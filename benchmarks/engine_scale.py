"""Engine-scale benchmark: scan simulator vs legacy loop, sharded (sort-free)
ProbAlloc vs the sorted baseline across K, and multi-job batching across J.

Rows (name,us_per_call,derived):
  engine/scan_sim            — compiled whole-horizon sim at K=100
  engine/loop_sim            — legacy per-round Python loop (baseline)
  engine/prob_alloc/K=...    — bisection allocator; derived carries the sorted
                               baseline time and (K <= 1e5) the max |p - ref|
                               error vs the paper's literal case enumeration
  engine/multi_job/J=...     — one batched dispatch vs J single dispatches

``--sharded`` runs the K-sharded suite instead (and writes
``BENCH_sharded.json``): whole-horizon sharded scans at D ∈ {1, 2, 4, 8},
`prob_alloc_shmap` vs the local bisection (plain and block-fused), and — full
protocol only — a K=1e7 lean horizon on the widest mesh.  ``--sharded-async``
runs the sharded *async* composition (``BENCH_sharded_async.json``): the
K=1e6 lean horizon at staleness S=2 on the D=8 mesh — staleness ring sharded
``(S, K/D)`` — next to the same-shape synchronous run for the overhead
ratio.  Forcing a multi-device CPU host requires
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax
initialises; when the flag is absent this script injects it for mesh runs.

CLI:  python benchmarks/engine_scale.py [--smoke] [--sharded | --sharded-async]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if ("--sharded" in sys.argv or "--sharded-async" in sys.argv) and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, reporter, save_json, time_fn
except ImportError:  # running as a script: python benchmarks/engine_scale.py
    from common import emit, reporter, save_json, time_fn

from repro.core.selection import prob_alloc, prob_alloc_reference
from repro.core.sim import selection_sim, selection_sim_loop
from repro.engine.multi_job import make_multi_job, multi_job_init, pack_jobs
from repro.engine.sharded import prob_alloc_sharded


def bench_sim(T: int, out: dict):
    t0 = time.perf_counter()
    selection_sim("e3cs", K=100, k=20, T=T, frac=0.5, backend="scan")  # compile + run
    scan_total = time.perf_counter() - t0
    t0 = time.perf_counter()
    selection_sim("e3cs", K=100, k=20, T=T, frac=0.5, backend="scan")  # steady state
    scan_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    selection_sim_loop("e3cs", K=100, k=20, T=T, frac=0.5)
    loop_s = time.perf_counter() - t0
    speedup = loop_s / scan_s
    out["sim"] = {
        "T": T, "scan_s": scan_s, "scan_with_compile_s": scan_total, "loop_s": loop_s,
        "speedup": speedup, "scan_rounds_per_s": T / scan_s,
    }
    emit("engine/scan_sim", scan_s / T * 1e6, f"T={T};speedup_vs_loop={speedup:.1f}x")
    emit("engine/loop_sim", loop_s / T * 1e6, f"T={T}")
    return speedup


def bench_prob_alloc(K_list, out: dict):
    rng = np.random.default_rng(0)
    rows = {}
    for K in K_list:
        k = max(1, K // 50)
        sigma = 0.5 * k / K
        w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))  # heavy tail => capping
        sorted_jit = jax.jit(prob_alloc, static_argnums=(1,))  # fair compiled baseline
        us_shard = time_fn(lambda: jax.block_until_ready(prob_alloc_sharded(w, k, sigma)[0]))
        us_sorted = time_fn(lambda: jax.block_until_ready(sorted_jit(w, k, sigma)[0]))
        derived = f"sorted_us={us_sorted:.1f}"
        err = None
        if K <= 100_000:  # the python reference enumerates K cases; skip at 1e6
            p, capped = prob_alloc_sharded(w, k, sigma)
            pr, cr = prob_alloc_reference(np.asarray(w), k, sigma)
            err = float(np.abs(np.asarray(p) - pr).max())
            derived += f";max_err_vs_ref={err:.2e};capped_match={bool((np.asarray(capped) == cr).all())}"
        rows[K] = {"k": k, "sharded_us": us_shard, "sorted_us": us_sorted, "max_err_vs_ref": err}
        emit(f"engine/prob_alloc/K={K}", us_shard, derived)
    out["prob_alloc"] = rows


def bench_multi_job(J_list, K: int, out: dict):
    rng = np.random.default_rng(1)
    rows = {}
    for J in J_list:
        Ks = [K] * J
        ks = [max(4, K // 50)] * J
        cfg, k_max = pack_jobs(Ks, ks, [0.5] * J, [0.5] * J)
        job_step, batched = make_multi_job(k_max)
        state = multi_job_init(cfg)
        keys = jax.random.split(jax.random.PRNGKey(0), J)
        xs = jnp.asarray((rng.random((J, K)) < 0.6).astype(np.float32))
        us_batched = time_fn(lambda: jax.block_until_ready(batched(cfg, state, keys, xs)[0].logw))
        single = jax.jit(job_step)
        row0 = jax.tree.map(lambda a: a[0], cfg)
        us_single = time_fn(lambda: jax.block_until_ready(single(row0, state.logw[0], state.t[0], keys[0], xs[0])[0]))
        amortized = us_batched / J
        rows[J] = {"batched_us": us_batched, "single_us": us_single, "amortized_us_per_job": amortized}
        emit(f"engine/multi_job/J={J}", us_batched, f"K={K};single_us={us_single:.1f};per_job={amortized:.1f}")
    out["multi_job"] = rows


def _time_sharded_run(run, state, key, xs, reps: int = 2):
    """Best-of-reps wall time plus the final run's outputs (so callers that
    report output-derived stats don't pay an extra horizon)."""
    out = run(state, key, xs)
    jax.block_until_ready(out[0].sel_counts)  # compile off the clock
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run(state, key, xs)
        jax.block_until_ready(out[0].sel_counts)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_sharded_scaling(D_list, K: int, T: int, block: int, out: dict):
    from repro.configs.base import FLConfig
    from repro.core.volatility import BernoulliVolatility, paper_success_rates
    from repro.engine.sharded import build_sharded_scan_runner
    from repro.launch.mesh import make_host_mesh

    k = max(100, K // 1000)
    rho = paper_success_rates(K)
    vol = BernoulliVolatility(jnp.asarray(rho))
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=0.5, allocator="bisect")
    key = jax.random.PRNGKey(0)
    xs = jnp.zeros((T, 0), jnp.float32)
    rows = {}
    base = None
    for D in D_list:
        run, state = build_sharded_scan_runner(fl, vol, rho, make_host_mesh(D), outputs="lean", block=block)
        best, _ = _time_sharded_run(run, state, key, xs)
        rps = T / best
        if base is None:
            base = rps
        rows[f"D={D}"] = {"K": K, "k": k, "T": T, "rounds_per_s": round(rps, 2), "vs_D1": round(rps / base, 2)}
        emit(f"engine/sharded/D={D}", best / T * 1e6, f"K={K};k={k};rounds_per_s={rps:.1f};vs_D1={rps / base:.2f}x")
    out["scaling"] = rows


def bench_sharded_alloc(D: int, K: int, block: int, out: dict):
    from repro.core.selection import prob_alloc_reference
    from repro.engine.sharded import masked_prob_alloc, prob_alloc_shmap
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(D)
    rng = np.random.default_rng(0)
    k = max(100, K // 50)
    sigma = 0.5 * k / K
    w = jnp.asarray(rng.gamma(0.3, 1.0, K).astype(np.float32))  # heavy tail => capping
    local = jax.jit(lambda w: masked_prob_alloc(w, k, sigma)[0])
    local_blk = jax.jit(lambda w: masked_prob_alloc(w, k, sigma, block=block)[0])
    shmap = jax.jit(lambda w: prob_alloc_shmap(w, k, sigma, mesh)[0])
    shmap_blk = jax.jit(lambda w: prob_alloc_shmap(w, k, sigma, mesh, block=block)[0])
    us = {name: time_fn(lambda f=f: jax.block_until_ready(f(w)))
          for name, f in [("local", local), (f"local_block{block}", local_blk),
                          (f"shmap_D{D}", shmap), (f"shmap_D{D}_block{block}", shmap_blk)]}
    err_blk = float(jnp.max(jnp.abs(local(w) - local_blk(w))))
    err_shm = float(jnp.max(jnp.abs(local(w) - shmap(w))))
    derived = f"local_us={us['local']:.0f};block_us={us[f'local_block{block}']:.0f};max_err_block={err_blk:.1e};max_err_shmap={err_shm:.1e}"
    if K <= 100_000:
        pr, _ = prob_alloc_reference(np.asarray(w), k, sigma)
        derived += f";max_err_vs_ref={np.abs(np.asarray(shmap(w)) - pr).max():.1e}"
    emit(f"engine/sharded/prob_alloc/K={K}", us[f"shmap_D{D}"], derived)
    out["alloc"] = {"K": K, "k": k, "D": D, "block": block, "us": us,
                    "max_err_block_vs_plain": err_blk, "max_err_shmap_vs_local": err_shm}


def bench_sharded_mega(D: int, K: int, T: int, block: int, out: dict):
    """The horizon a single device cannot sensibly hold: every per-client
    vector in the compiled round divides by D."""
    from repro.configs.base import FLConfig
    from repro.core.volatility import BernoulliVolatility, paper_success_rates
    from repro.engine.sharded import build_sharded_scan_runner
    from repro.launch.mesh import make_host_mesh

    k = K // 1000
    rho = paper_success_rates(K)
    vol = BernoulliVolatility(jnp.asarray(rho))
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=0.5, allocator="bisect")
    run, state = build_sharded_scan_runner(fl, vol, rho, make_host_mesh(D), outputs="lean", block=block)
    best, _ = _time_sharded_run(run, state, jax.random.PRNGKey(0), jnp.zeros((T, 0), jnp.float32), reps=1)
    rps = T / best
    out["mega"] = {
        "K": K, "k": k, "T": T, "D": D, "rounds_per_s": round(rps, 2),
        "client_decisions_per_s": round(K * rps, 0),
        "per_device_state_mb": round(4.0 * K / D / 1e6, 1),
    }
    emit(f"engine/sharded/mega/K={K}", best / T * 1e6, f"D={D};rounds_per_s={rps:.2f}")


def bench_sharded_async(D: int, K: int, T: int, S: int, block: int, out: dict, rep=None):
    """The sharded-async composition: lag-model outcomes, the ``(S, K/D)``-
    sharded staleness ring and the K-sharded allocator/top-k in ONE compiled
    lean horizon, next to the same-shape sync run for the overhead ratio.
    The async horizon runs with the in-scan taps stage enabled — the timing
    measures the instrumented engine, and the tap series feed the windowed
    ``metrics`` stream on the reporter.  A third timed run adds the
    client-axis sketch stage (window W = T // 2, i.e. 50 at the full
    protocol) on top of taps: ``sketch_rounds_per_s`` gates like any
    throughput leaf, ``sketch_overhead_x`` records the cost vs taps-only
    (the acceptance bar is <= 1.15x), and the psum-merged sketch stream
    feeds the ``fairness`` metrics stream + the alert detector pass."""
    from repro.configs.base import FLConfig
    from repro.core.volatility import BernoulliVolatility, CompletionLag, paper_success_rates
    from repro.engine.round_program import RoundProgram
    from repro.launch.mesh import make_host_mesh
    from repro.obs import ROUND_TAPS, SketchSpec

    k = max(100, K // 1000)
    rho = paper_success_rates(K)
    base = BernoulliVolatility(jnp.asarray(rho))
    mesh = make_host_mesh(D)
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota_frac=0.5, allocator="bisect")
    key = jax.random.PRNGKey(0)
    xs = jnp.zeros((T, 0), jnp.float32)

    lag = CompletionLag(base, p_late=0.7, lag_decay=0.5, max_lag=S)
    pa = RoundProgram(fl=fl, vol=lag, rho=rho, staleness=S, alpha=0.5, mesh=mesh, block=block)
    run_a, st_a = pa.build_runner(outputs="lean", taps=True)
    best_a, (state, on_time, stale, _, taps) = _time_sharded_run(run_a, st_a, key, xs)
    if rep is not None:
        rep.metrics_stream(
            "sharded_async",
            {n: np.asarray(v) for n, v in taps["series"].items()},
            window=max(1, T // 10),
            better=ROUND_TAPS.directions(),
        )
        out["tap_counters"] = {n: float(v) for n, v in taps["counters"].items()}

    W_sk = max(1, T // 2)  # 50 at the full T=100 protocol, 15 under smoke
    sk_spec = SketchSpec(window=W_sk, n_regions=4)
    run_k, st_k = pa.build_runner(outputs="lean", taps=True, sketch=sk_spec)
    best_k, (_, _, _, _, taps_k) = _time_sharded_run(run_k, st_k, key, xs)
    sketch_overhead = best_k / best_a
    out["sketch"] = {
        "window": W_sk, "n_regions": sk_spec.n_regions,
        "sketch_rounds_per_s": round(T / best_k, 2),
        "sketch_overhead_x": round(sketch_overhead, 3),
    }
    emit(
        f"engine/sharded_async_sketch/K={K}",
        best_k / T * 1e6,
        f"D={D};W={W_sk};rounds_per_s={T / best_k:.2f};overhead_vs_taps={sketch_overhead:.3f}x",
    )
    if rep is not None:
        fair = rep.fairness_stream("fairness", taps_k["sketches"])
        rep.alerts(
            series={n: np.asarray(v) for n, v in taps_k["series"].items()},
            fairness=fair,
            expected_selected=k,
        )

    # the fused round path (one dispatch for allocate-epilogue/perturb/top-k
    # and one for the observe/update/credit tail), timed with the identical
    # taps=True instrumentation so the ratio is apples-to-apples with best_a
    pf = RoundProgram(fl=fl, vol=lag, rho=rho, staleness=S, alpha=0.5, mesh=mesh,
                      block=block, fused=True)
    run_f, st_f = pf.build_runner(outputs="lean", taps=True)
    best_f, _ = _time_sharded_run(run_f, st_f, key, xs)
    fused_speedup = best_a / best_f
    emit(
        f"engine/sharded_async_fused/K={K}",
        best_f / T * 1e6,
        f"D={D};S={S};rounds_per_s={T / best_f:.2f};speedup_vs_staged={fused_speedup:.3f}x",
    )

    ps = RoundProgram(fl=fl, vol=base, rho=rho, mesh=mesh, block=block)
    run_s, st_s = ps.build_runner(outputs="lean")
    best_s, _ = _time_sharded_run(run_s, st_s, key, xs)

    rps = T / best_a
    overhead = best_a / best_s
    out["sharded_async"] = {
        "K": K, "k": k, "T": T, "D": D, "staleness": S, "alpha": 0.5, "bisect_block": block,
        "rounds_per_s": round(rps, 2),
        "client_decisions_per_s": round(K * rps, 0),
        "sync_rounds_per_s": round(T / best_s, 2),
        "async_overhead_x": round(overhead, 2),
        "fused_rounds_per_s": round(T / best_f, 2),
        "fused_speedup_x": round(fused_speedup, 3),
        "on_time_total": float(np.asarray(on_time).sum()),
        "stale_credit_total": float(np.asarray(stale).sum()),
        "ring_mb_per_device": round(4.0 * S * K / D / 1e6, 2),
    }
    emit(
        f"engine/sharded_async/K={K}",
        best_a / T * 1e6,
        f"D={D};S={S};rounds_per_s={rps:.2f};overhead_vs_sync={overhead:.2f}x;stale={float(np.asarray(stale).sum()):.0f}",
    )


def run_sharded_async(smoke: bool = False):
    out = {"host_devices": len(jax.devices()), "cpu_count": os.cpu_count()}
    D = min(8, len(jax.devices()))
    rep = reporter("sharded_async", config={"smoke": smoke, "D": D})
    if smoke:
        bench_sharded_async(D, 1_000_000, 30, 2, 4, out, rep)
    else:
        bench_sharded_async(D, 1_000_000, 100, 2, 4, out, rep)
    rep.save(out)
    return out


def run_sharded(smoke: bool = False):
    out = {"host_devices": len(jax.devices()), "cpu_count": os.cpu_count()}
    n_dev = len(jax.devices())
    D_list = [d for d in (1, 2, 4, 8) if d <= n_dev]
    block = 4
    if smoke:
        bench_sharded_scaling(D_list, 200_000, 30, block, out)
        bench_sharded_alloc(min(8, n_dev), 100_000, block, out)
    else:
        bench_sharded_scaling(D_list, 1_000_000, 100, block, out)
        bench_sharded_alloc(min(8, n_dev), 1_000_000, block, out)
        bench_sharded_mega(min(8, n_dev), 10_000_000, 40, block, out)
    save_json("sharded", out)
    return out


def run(smoke: bool = False):
    out = {}
    T = 300 if smoke else 2500
    K_list = [1_000, 10_000] if smoke else [1_000, 10_000, 100_000, 1_000_000]
    J_list = [1, 8] if smoke else [1, 8, 64]
    speedup = bench_sim(T, out)
    bench_prob_alloc(K_list, out)
    bench_multi_job(J_list, 1_000 if smoke else 10_000, out)
    save_json("engine_scale", out)
    if speedup < 5.0:
        print(f"engine_scale,0,WARN:scan_speedup_{speedup:.1f}x_below_5x", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced CPU/CI protocol")
    ap.add_argument("--sharded", action="store_true", help="run the K-sharded mesh suite (only)")
    ap.add_argument("--sharded-async", action="store_true",
                    help="run the sharded-async composition suite (K=1e6, S=2, widest mesh)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.sharded_async:
        run_sharded_async(smoke=args.smoke)
    elif args.sharded:
        run_sharded(smoke=args.smoke)
    else:
        run(smoke=args.smoke)


if __name__ == "__main__":
    sys.path.insert(0, ".")
    main()
