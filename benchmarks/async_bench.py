"""Async-engine benchmark: staleness-aware scan rounds at scale and the
compiled steady-state serving loop vs the per-tick host loop.

Rows (name,us_per_call,derived):
  async/scan/K=...        — whole-horizon async engine (CompletionLag outcome
                            draw, S-round staleness ring, lean outputs);
                            derived carries rounds/sec, the sync lean
                            baseline, and the recovered effective
                            participation (staleness-aware CEP vs on-time)
  async/overhead/K=...    — S=0 BinaryLag async runner vs the legacy sync
                            runner: the price of the generalised round body
                            when the buffer is disabled (should be ~1x)
  async/serve/J=...       — compiled lax.scan service loop (sync and async)
                            vs the per-tick host loop, ticks/sec

The full protocol (no ``--smoke``) runs the K=1e6, T=2500 lean-mode horizon
at S=2 on one CPU host — the acceptance scale.

CLI:  python benchmarks/async_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, reporter
except ImportError:  # running as a script: python benchmarks/async_bench.py
    from common import emit, reporter

from repro.obs import ROUND_TAPS

from repro.configs.base import FLConfig
from repro.core.volatility import BinaryLag, CompletionLag, make_volatility, paper_success_rates
from repro.engine.scan_sim import build_scan_runner
from repro.launch.select_serve import run_service, run_service_compiled


def _time_runner(run, state0, key, xs_in, reps: int = 3):
    jax.block_until_ready(run(state0, key, xs_in)[0].sel_counts)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run(state0, key, xs_in)
        jax.block_until_ready(out[0].sel_counts)
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_async_scan(K_list, T: int, S: int, alpha: float, out: dict, reps: int = 3, rep=None):
    rows = {}
    for K in K_list:
        k = max(1, K // 50)
        rho = jnp.asarray(paper_success_rates(K))
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="const", quota_frac=0.5)
        xs_in = jnp.zeros((T, 0), jnp.float32)
        key = jax.random.PRNGKey(0)

        lag = CompletionLag(make_volatility("bernoulli", rho), p_late=0.7, lag_decay=0.5, max_lag=S)
        run_a, st_a = build_scan_runner(fl, lag, rho, outputs="lean", staleness=S, alpha=alpha, taps=True)
        async_s, aout = _time_runner(run_a, st_a, key, xs_in, reps)
        state = aout[0]
        acep, on_time = float(state.cep), float(state.succ_hist)
        tap_counters = None
        if rep is not None:
            taps = aout[-1]
            rep.metrics_stream(
                f"async_scan_K{K}",
                {name: np.asarray(v) for name, v in taps["series"].items()},
                window=max(1, T // 10),
                better=ROUND_TAPS.directions(),
            )
            tap_counters = {n: float(v) for n, v in taps["counters"].items()}

        sync_vol = make_volatility("bernoulli", rho)
        run_s, st_s = build_scan_runner(fl, sync_vol, rho, outputs="lean")
        sync_s, _ = _time_runner(run_s, st_s, key, xs_in, reps)

        recovered = (acep - on_time) / max(on_time, 1.0)
        derived = (
            f"T={T};S={S};rounds_per_s={T / async_s:.1f};sync_rounds_per_s={T / sync_s:.1f}"
            f";stale_recovered_frac={recovered:.3f}"
        )
        rows[K] = {
            "T": T, "k": k, "S": S, "alpha": alpha,
            "async_s": async_s, "rounds_per_s": T / async_s,
            "sync_s": sync_s, "sync_rounds_per_s": T / sync_s,
            "async_cep": acep, "on_time": on_time, "stale_recovered_frac": recovered,
        }
        if tap_counters is not None:
            rows[K]["tap_counters"] = tap_counters
        emit(f"async/scan/K={K}", async_s / T * 1e6, derived)
    out["scan"] = rows
    return rows


def bench_overhead(K: int, T: int, out: dict, reps: int = 3):
    """S=0 BinaryLag vs the legacy sync runner: same semantics, same bits —
    the async round body must not tax the synchronous configuration."""
    k = max(1, K // 50)
    rho = jnp.asarray(paper_success_rates(K))
    fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="const", quota_frac=0.5)
    xs_in = jnp.zeros((T, 0), jnp.float32)
    key = jax.random.PRNGKey(0)

    run_b, st_b = build_scan_runner(
        fl, BinaryLag(make_volatility("bernoulli", rho)), rho, outputs="lean", staleness=0
    )
    s0_s, _ = _time_runner(run_b, st_b, key, xs_in, reps)
    run_s, st_s = build_scan_runner(fl, make_volatility("bernoulli", rho), rho, outputs="lean")
    sync_s, _ = _time_runner(run_s, st_s, key, xs_in, reps)
    ratio = s0_s / sync_s
    out["overhead"] = {"K": K, "T": T, "s0_s": s0_s, "sync_s": sync_s, "ratio": ratio}
    emit(f"async/overhead/K={K}", s0_s / T * 1e6, f"T={T};vs_sync_ratio={ratio:.2f}")
    return ratio


def bench_serve(J: int, K_max: int, rounds: int, S: int, out: dict):
    host = run_service(J=J, K_max=K_max, rounds=rounds, seed=0)
    sync = run_service_compiled(J=J, K_max=K_max, rounds=rounds, seed=0, staleness=0)
    asyn = run_service_compiled(J=J, K_max=K_max, rounds=rounds, seed=0, staleness=S)
    speed_sync = sync["ticks_per_s"] / host["ticks_per_s"]
    speed_async = asyn["ticks_per_s"] / host["ticks_per_s"]
    out["serve"] = {"host": host, "compiled_sync": sync, "compiled_async": asyn,
                    "speedup_sync": speed_sync, "speedup_async": speed_async}
    emit(
        f"async/serve/J={J}",
        asyn["tick_us"],
        f"K_max={K_max};ticks_per_s={asyn['ticks_per_s']};host_ticks_per_s={host['ticks_per_s']}"
        f";speedup_vs_host={speed_async:.1f}x;sync_speedup={speed_sync:.1f}x",
    )
    return speed_async


def run(smoke: bool = False):
    out = {}
    rep = reporter("async", config={"smoke": smoke})
    if smoke:
        bench_async_scan([10_000], T=128, S=2, alpha=0.5, out=out, rep=rep)
        bench_overhead(K=10_000, T=128, out=out)
        bench_serve(J=4, K_max=512, rounds=10, S=2, out=out)
    else:
        # acceptance scale: the full K=1e6 x T=2500 horizon, S=2, on one host
        bench_async_scan([100_000, 1_000_000], T=2500, S=2, alpha=0.5, out=out, reps=1, rep=rep)
        bench_overhead(K=100_000, T=500, out=out)
        bench_serve(J=8, K_max=65_536, rounds=30, S=2, out=out)
    rep.save(out)
    if out["overhead"]["ratio"] > 1.5:
        print(f"async,0,WARN:s0_overhead_{out['overhead']['ratio']:.2f}x_above_1.5x", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced CPU/CI protocol")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    sys.path.insert(0, ".")
    main()
