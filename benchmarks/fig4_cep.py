"""Fig. 4 — success-ratio and Cumulative Effective Participation (CEP)
trajectories per scheme."""
from __future__ import annotations

import time

import numpy as np

from repro.core.sim import selection_sim

from .common import QUICK, emit, save_json
from .fig3_selection import SCHEMES


def run():
    T = 500 if QUICK else 2500
    out = {}
    for name, kw in SCHEMES:
        t0 = time.perf_counter()
        sim = selection_sim(T=T, **kw)
        us = (time.perf_counter() - t0) / T * 1e6
        eff = (sim["masks"] * sim["xs"]).sum(1)  # per-round effective returns
        cep = np.cumsum(eff)
        rounds = np.arange(1, T + 1)
        succ_ratio = cep / (rounds * 20)
        q = max(1, T // 50)
        out[name] = {
            "rounds": rounds[::q].tolist(),
            "cep": cep[::q].tolist(),
            "success_ratio": succ_ratio[::q].tolist(),
            "final_cep": float(cep[-1]),
            "cep_at_T4": float(cep[T // 4 - 1]),
        }
        emit(f"fig4/{name}", us, f"final_cep={cep[-1]:.0f};cep@T/4={cep[T//4-1]:.0f};succ={succ_ratio[-1]:.3f}")
    order = sorted(out, key=lambda n: -out[n]["final_cep"])
    save_json("fig4_cep", {"rounds": T, "schemes": out, "cep_order": order})
    return out


if __name__ == "__main__":
    run()
