"""§Roofline — aggregate the dry-run JSONs into the per-(arch x shape x mesh)
three-term roofline table (compute / memory / collective seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS utilisation)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit, save_json

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "results/dryrun")


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run():
    rows = []
    for rec in load_records():
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec["status"] != "ok":
            emit(f"roofline/{name}", 0.0, rec["status"])
            continue
        r = rec["roofline"]
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = r["compute_s"] / total if total else 0.0
        rows.append(
            dict(
                name=name,
                arch=rec["arch"],
                shape=rec["shape"],
                mesh=rec["mesh"],
                compute_s=r["compute_s"],
                memory_s=r["memory_s"],
                collective_s=r["collective_s"],
                bottleneck=r["bottleneck"],
                hbm_gb=rec.get("per_device_hbm_gb"),
                useful_ratio=rec.get("useful_flops_ratio"),
                compute_frac=frac,
            )
        )
        emit(
            f"roofline/{name}",
            total * 1e6,
            f"bottleneck={r['bottleneck']};compute_frac={frac:.3f};useful={rec.get('useful_flops_ratio', 0) or 0:.3f}",
        )
    save_json("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
