"""Serving front end benchmark: tick latency and saturation over the wire.

Measures the full loopback path — client socket -> framing -> admission
queue -> streaming batcher -> vmapped slot engine -> response — not the
bare engine step, because coordinator-side latency is what a fleet
actually observes.

Rows (name,us_per_call,derived):
  serve/closed/J=...     — closed-loop saturation: J tenant jobs, each with
                           its own connection, ticking as fast as the
                           server answers; us per tick end-to-end, derived
                           carries ticks/sec and the mean coalesced batch
                           width (the batcher's whole point: width -> J as
                           clients pile up)
  serve/load/r=...       — offered-load sweep: J clients posting at a target
                           aggregate rate r ticks/sec against a small
                           admission queue; derived carries achieved rate,
                           client-observed p50/p99 ms and sheds (the
                           backpressure path under overload)

Bench JSON (gated by scripts/check_bench.py against
results/bench/baseline/BENCH_serve_front.json):
  closed_ticks_per_s     — the gated saturation scalar (*_per_s convention)
  hists.*                — client/dispatch latency histograms (reported,
                           never gated: wall-clock quantiles are too noisy
                           to diff across CI machines)
  metrics.serve          — the windowed ``serve`` tap-group stream
                           (queue_depth / batch_jobs / shed) sampled per
                           dispatch on the server, gate direction
                           ``shed: lower``

CLI:  python benchmarks/serve_front.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

try:
    from .common import emit, reporter
except ImportError:  # running as a script
    from common import emit, reporter

from repro.obs import LatencyHistogram
from repro.serve import SelectionServer, ServeClient, ServeError, SlotEngine


def _drive_closed(address, spec: dict, rounds: int, hist: LatencyHistogram, lock):
    """One closed-loop tenant: admit, then tick back-to-back."""
    with ServeClient.connect(address) as c:
        job = c.admit(**spec)
        bits = np.ones(spec["K"])
        for _ in range(rounds):
            t0 = time.perf_counter()
            c.tick(job, bits=bits)
            dt = time.perf_counter() - t0
            with lock:
                hist.observe(dt)


def bench_closed_loop(J: int, K: int, rounds: int, rep) -> float:
    # J timed tenants + 1 warm tenant share one slot bucket: the timed phase
    # reuses the exact compiled step the warmup built
    srv = SelectionServer(SlotEngine(K_max=K, k_cap=max(8, K // 8), buckets=(J + 1,)))
    hist = LatencyHistogram(lo=1e-5, hi=10.0)
    lock = threading.Lock()
    with srv:
        # warm the compiled step before timing (a throwaway tenant hits the
        # same J-bucket step the timed tenants will reuse)
        _drive_closed(srv.address, dict(K=K, k=K // 16, seed=99), 2, LatencyHistogram(), lock)
        warm_dispatches = srv.stats["dispatches"]
        t0 = time.perf_counter()
        threads = [
            threading.Thread(
                target=_drive_closed,
                args=(srv.address, dict(K=K, k=K // 16, seed=i), rounds, hist, lock),
            )
            for i in range(J)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        ticks = J * rounds
        width = ticks / max(srv.stats["dispatches"] - warm_dispatches, 1)
        srv.attach_report(rep)
    ticks_per_s = ticks / wall
    emit(
        f"serve/closed/J={J}",
        wall / ticks * 1e6,
        f"K={K};ticks_per_s={ticks_per_s:.0f};mean_batch={width:.2f}",
    )
    rep.histogram("client_closed", hist)
    rep.update(closed_ticks_per_s=ticks_per_s, closed_mean_batch=width)
    return ticks_per_s


def bench_offered_load(J: int, K: int, rates, seconds: float, rep) -> None:
    """Sweep target aggregate rates; under overload the bounded queue sheds
    rather than stretching the tail."""
    for rate in rates:
        srv = SelectionServer(
            SlotEngine(K_max=K, k_cap=max(8, K // 8), buckets=(J,)), max_queue=8
        )
        hist = LatencyHistogram(lo=1e-5, hi=10.0)
        lock = threading.Lock()
        done = 0
        shed = 0

        def drive(i):
            nonlocal done, shed
            interval = J / rate
            with ServeClient.connect(srv.address) as c:
                job = c.admit(K=K, k=K // 16, seed=i)
                bits = np.ones(K)
                deadline = time.perf_counter() + seconds
                while time.perf_counter() < deadline:
                    t0 = time.perf_counter()
                    try:
                        c.tick(job, bits=bits)
                        with lock:
                            hist.observe(time.perf_counter() - t0)
                            done += 1
                    except ServeError:
                        with lock:
                            shed += 1
                    time.sleep(max(0.0, interval - (time.perf_counter() - t0)))

        with srv:
            threads = [threading.Thread(target=drive, args=(i,)) for i in range(J)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        achieved = done / seconds
        p50 = hist.quantile(0.5) * 1e3
        p99 = hist.quantile(0.99) * 1e3
        emit(
            f"serve/load/r={rate}",
            (1.0 / max(achieved, 1e-9)) * 1e6,
            f"achieved_per_s={achieved:.0f};p50_ms={p50:.2f};p99_ms={p99:.2f};shed={shed}",
        )
        rep.histogram(f"client_load_r{rate}", hist)
        rep.update(**{f"load_r{rate}_achieved": achieved, f"load_r{rate}_shed": shed})


def run(smoke: bool = True) -> None:
    J = 4 if smoke else 16
    K = 256 if smoke else 4096
    rounds = 40 if smoke else 400
    rep = reporter("serve_front", config={"smoke": smoke, "J": J, "K": K, "rounds": rounds})
    sat = bench_closed_loop(J, K, rounds, rep)
    # sweep from comfortable to past saturation
    rates = [max(10, int(sat * f)) for f in ((0.5, 2.0) if smoke else (0.25, 0.5, 1.0, 2.0))]
    bench_offered_load(J, K, rates, seconds=1.5 if smoke else 10.0, rep=rep)
    rep.save()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main(sys.argv[1:])
