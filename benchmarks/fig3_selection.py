"""Fig. 3 — selection-count distribution over the 4 volatility classes, per
selection scheme (2500 rounds, K=100, k=20)."""
from __future__ import annotations

import time


from repro.core.fairness import class_selection_stats, jain_index
from repro.core.sim import selection_sim

from .common import QUICK, emit, save_json

SCHEMES = [
    ("FedCS", dict(scheme="fedcs")),
    ("E3CS-0", dict(scheme="e3cs", frac=0.0)),
    ("E3CS-0.5", dict(scheme="e3cs", frac=0.5)),
    ("E3CS-0.8", dict(scheme="e3cs", frac=0.8)),
    ("E3CS-inc", dict(scheme="e3cs", quota="inc")),
    ("Random", dict(scheme="random")),
    ("pow-d", dict(scheme="pow_d")),
    ("UCB*", dict(scheme="ucb")),  # beyond-paper reference
]


def run():
    T = 500 if QUICK else 2500
    out = {}
    for name, kw in SCHEMES:
        t0 = time.perf_counter()
        sim = selection_sim(T=T, **kw)
        us = (time.perf_counter() - t0) / T * 1e6
        stats = class_selection_stats(sim["counts"], [25, 25, 25, 25])
        import jax.numpy as jnp

        out[name] = {
            "per_class": stats,
            "jain": float(jain_index(jnp.asarray(sim["counts"]))),
            "class_means": [s["mean"] for s in stats],
        }
        emit(f"fig3/{name}", us, f"jain={out[name]['jain']:.3f};class_means={[round(m,1) for m in out[name]['class_means']]}")
    save_json("fig3_selection", {"rounds": T, "schemes": out})
    return out


if __name__ == "__main__":
    run()
