"""Chaos benchmark: serving throughput and recovery latency under faults.

Runs the real loopback serving path — retrying clients against a
``SelectionServer`` — with a seeded :class:`repro.serve.faults.FaultPlan`
injecting engine crashes, checkpoint corruption, dropped connections and
slow dispatches, then measures what the fault-tolerance layer costs:
every tenant still completes its full horizon (supervised recovery +
round-desync replay guarantee it), so the gated number is end-to-end
throughput *including* the crashes, restores and replays.

Rows (name,us_per_call,derived):
  serve/chaos/J=...      — us per completed tick under the chaos schedule;
                           derived carries ok ticks/sec, supervised
                           restarts, recovery seconds, and the fired fault
                           counts (crash/corrupt/drop/slow)

Bench JSON (gated by scripts/check_bench.py against
results/bench/baseline/BENCH_serve_chaos.json):
  chaos_ok_ticks_per_s   — the gated scalar (*_per_s convention):
                           completed ticks over wall clock, faults included
  restarts, recovery_s_total, replayed, rewinds, fired_* — recovery
                           telemetry (reported, never gated: wall-clock
                           recovery latency is machine-dependent)
  metrics.serve          — the windowed ``serve`` tap-group stream, now
                           carrying the ``restarts`` / ``recovery_s``
                           gauges next to queue_depth / batch_jobs / shed
  alerts                 — the ``engine_restart`` events the supervisor
                           raised during the run

CLI:  python benchmarks/serve_chaos.py [--smoke]
"""
from __future__ import annotations

import argparse
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

try:
    from .common import emit, reporter
except ImportError:  # running as a script
    from common import emit, reporter

from repro.serve import FaultPlan, SelectionServer, ServeClient, ServeError, SlotEngine


def _drive(address, spec: dict, rounds: int, seed: int, counts, lock):
    """One retrying tenant: round-tagged ticks, rewinding on the
    ``round_desync`` a supervised recovery hands back."""
    with ServeClient.connect(address, retries=8, seed=seed) as c:
        job = c.admit(**spec)
        bits = np.ones(spec["K"])
        t = 0
        while t < rounds:
            try:
                out = c.tick(job, bits=bits, round=t)
            except ServeError as e:
                if e.code == "round_desync":
                    with lock:
                        counts["rewinds"] += 1
                    t = int(e.response["expected"])
                    continue
                raise
            with lock:
                counts["ok"] += 1
            t = out["round"] + 1


def bench_chaos(J: int, K: int, rounds: int, seed: int, rep) -> float:
    # the seeded schedule: 1 crash, 1 corrupted checkpoint write, 2 dropped
    # connections, 1 slow dispatch — drawn once, bit-reproducible.
    # first_step clears the J admit responses so a drop never cuts a
    # non-idempotent admit reply.
    plan = FaultPlan.sample(
        seed, n_steps=rounds, crashes=1, corruptions=1, drops=2, slow=1,
        slow_s=0.005, first_step=J + 2,
    )
    ckpt_dir = tempfile.mkdtemp(prefix="serve_chaos_")
    srv = SelectionServer(
        SlotEngine(K_max=K, k_cap=max(8, K // 8), buckets=(J,)),
        ckpt_dir=ckpt_dir, ckpt_every=max(2, rounds // 6), ckpt_keep=4,
        faults=plan, restart_backoff=0.01,
    )
    counts = {"ok": 0, "rewinds": 0}
    lock = threading.Lock()
    try:
        with srv:
            t0 = time.perf_counter()
            threads = [
                threading.Thread(
                    target=_drive,
                    args=(srv.address, dict(K=K, k=max(4, K // 16), seed=seed + i),
                          rounds, seed + i, counts, lock),
                )
                for i in range(J)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            srv.attach_report(rep, window=4)
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    fired = plan.fired()
    ok = counts["ok"]
    assert ok >= J * rounds, (ok, J, rounds)  # every tenant finished its horizon
    ok_per_s = ok / wall
    recovery_s = float(sum(srv.recoveries))
    emit(
        f"serve/chaos/J={J}",
        wall / ok * 1e6,
        f"K={K};ok_per_s={ok_per_s:.0f};restarts={srv.stats['restarts']};"
        f"recovery_s={recovery_s:.3f};fired=" +
        "/".join(f"{k}:{v}" for k, v in sorted(fired.items())),
    )
    rep.update(
        chaos_ok_ticks_per_s=ok_per_s,
        restarts=srv.stats["restarts"],
        recovery_s_total=recovery_s,
        replayed=srv.stats["replayed"],
        rewinds=counts["rewinds"],
        **{f"fired_{k}": v for k, v in fired.items()},
    )
    return ok_per_s


def run(smoke: bool = True) -> None:
    J = 4 if smoke else 8
    K = 256 if smoke else 2048
    rounds = 24 if smoke else 120
    rep = reporter("serve_chaos", config={"smoke": smoke, "J": J, "K": K, "rounds": rounds})
    bench_chaos(J, K, rounds, seed=0, rep=rep)
    rep.save()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main(sys.argv[1:])
