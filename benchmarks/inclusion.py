"""Beyond-paper — inclusion-probability fidelity of the two samplers.

The paper assumes ``E[1{i in A_t}] = p_i`` (footnote 6); Plackett-Luce
(torch.multinomial w/o replacement == Gumbel top-k) only approximates this.
Madow systematic sampling achieves it exactly.  This benchmark quantifies the
gap as a function of allocation skew."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection import prob_alloc
from repro.core.selection.sampling import inclusion_probability_mc

from .common import QUICK, emit, save_json


def run():
    K, k = 40, 8
    n_mc = 2000 if QUICK else 20000
    rng = np.random.default_rng(0)
    out = {}
    for skew in (0.1, 1.0, 3.0):
        w = jnp.asarray(np.exp(skew * rng.normal(size=K)).astype(np.float32))
        p, _ = prob_alloc(w, k, 0.1 * k / K)
        for m in ("plackett_luce", "systematic"):
            inc = inclusion_probability_mc(jax.random.PRNGKey(1), p, k, n_mc, m)
            err = float(jnp.abs(inc - p).max())
            l1 = float(jnp.abs(inc - p).sum())
            out[f"skew{skew}_{m}"] = {"max_err": err, "l1_err": l1}
            emit(f"inclusion/skew{skew}_{m}", 0.0, f"max_err={err:.4f};l1={l1:.4f}")
    save_json("inclusion", out)
    return out


if __name__ == "__main__":
    run()
