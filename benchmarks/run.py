"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage::

    python -m benchmarks.run [names ...] [--smoke]

Positional ``names`` select a subset (default: everything); ``--smoke``
forces the reduced CI protocol regardless of env.  Also honors:
  REPRO_BENCH_QUICK=0   full paper-scale protocol (hours on this CPU box)
  REPRO_BENCH_ONLY=a,b  subset of benches (when no positional names given)
"""
import argparse
import os
import sys
import traceback


def main(argv=None) -> None:
    from . import async_bench, engine_scale, fig3_selection, fig4_cep, fig7_cardinality, inclusion, kernels, regret, roofline, scenarios_bench, serve_chaos, serve_front, table_training

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", help="benches to run (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="force the reduced CI protocol (overrides REPRO_BENCH_QUICK)")
    args = ap.parse_args(argv)

    quick = args.smoke or os.environ.get("REPRO_BENCH_QUICK", "1") == "1"
    benches = {
        "fig3": fig3_selection.run,
        "fig4": fig4_cep.run,
        "fig7": fig7_cardinality.run,
        "regret": regret.run,
        "inclusion": inclusion.run,
        "kernels": lambda: kernels.run(smoke=quick),
        "roofline": roofline.run,
        "tables": table_training.run,
        "engine": lambda: engine_scale.run(smoke=quick),
        "scenarios": lambda: scenarios_bench.run(smoke=quick),
        "async": lambda: async_bench.run(smoke=quick),
        "serve": lambda: serve_front.run(smoke=quick),
        "serve_chaos": lambda: serve_chaos.run(smoke=quick),
    }
    only = os.environ.get("REPRO_BENCH_ONLY")
    names = args.names or (only.split(",") if only else list(benches))
    unknown = [n for n in names if n not in benches]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; available: {', '.join(benches)}")
    failed = []
    print("name,us_per_call,derived")
    for n in names:
        try:
            benches[n]()
        except Exception as e:  # noqa: BLE001
            failed.append(n)
            print(f"{n},0,FAILED:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
