"""Benchmark harness entrypoint — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Honors:
  REPRO_BENCH_QUICK=0   full paper-scale protocol (hours on this CPU box)
  REPRO_BENCH_ONLY=a,b  subset of benches to run
"""
import os
import sys
import traceback


def main() -> None:
    from . import async_bench, engine_scale, fig3_selection, fig4_cep, fig7_cardinality, inclusion, kernels, regret, roofline, scenarios_bench, serve_chaos, serve_front, table_training

    quick = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"
    benches = {
        "fig3": fig3_selection.run,
        "fig4": fig4_cep.run,
        "fig7": fig7_cardinality.run,
        "regret": regret.run,
        "inclusion": inclusion.run,
        "kernels": kernels.run,
        "roofline": roofline.run,
        "tables": table_training.run,
        "engine": lambda: engine_scale.run(smoke=quick),
        "scenarios": lambda: scenarios_bench.run(smoke=quick),
        "async": lambda: async_bench.run(smoke=quick),
        "serve": lambda: serve_front.run(smoke=quick),
        "serve_chaos": lambda: serve_chaos.run(smoke=quick),
    }
    only = os.environ.get("REPRO_BENCH_ONLY")
    names = only.split(",") if only else list(benches)
    failed = []
    print("name,us_per_call,derived")
    for n in names:
        try:
            benches[n]()
        except Exception as e:  # noqa: BLE001
            failed.append(n)
            print(f"{n},0,FAILED:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
