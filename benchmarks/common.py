"""Shared helpers for the benchmark suite.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
convention) plus richer JSON dropped under ``results/bench/`` as
``BENCH_<name>.json`` — the glob CI uploads as per-run artifacts so the
perf trajectory is captured per-PR.
"""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS = os.environ.get("REPRO_BENCH_OUT", "results/bench")
QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, obj):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"BENCH_{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=float)


def time_fn(fn, *args, iters: int = 10, warmup: int = 2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us
