"""Shared helpers for the benchmark suite.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
convention) plus richer JSON dropped under the bench dir (see
``repro.obs.paths``) as ``BENCH_<name>.json`` — the glob CI uploads as
per-run artifacts so the perf trajectory is captured per-PR.

Since the metrics spine, emission goes through ``repro.obs.Reporter``:
``reporter(name)`` returns a Reporter whose ``save`` writes the bench
JSON (with any attached windowed ``metrics`` streams) AND a paired JSONL
run log under ``<results>/runlogs/``.  ``emit`` / ``save_json`` keep the
historical call surface for simple benches.
"""
from __future__ import annotations

import os
import time

import jax

from repro.obs import Reporter
from repro.obs.paths import bench_dir

RESULTS = bench_dir()  # legacy name; prefer repro.obs.paths at call time
QUICK = os.environ.get("REPRO_BENCH_QUICK", "1") == "1"


def reporter(name: str, config=None) -> Reporter:
    """The unified per-benchmark reporter (bench JSON + JSONL run log)."""
    return Reporter(name, config=config)


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def save_json(name: str, obj):
    """Write ``BENCH_<name>.json`` through the unified reporter (keeps the
    one-shot call surface; also emits the paired run log)."""
    Reporter(name).save(obj)


def time_fn(fn, *args, iters: int = 10, warmup: int = 2, blocking: bool = True):
    """Mean wall-clock microseconds per call.

    ``blocking=True`` (default) blocks on every call's outputs, so the
    figure is true per-call latency.  ``blocking=False`` restores the old
    pipelined-dispatch timing — calls are enqueued back-to-back and only
    the last result is synced — which measures sustained dispatch
    throughput but can understate per-call cost for multi-output fns
    (device work overlaps host dispatch of the next call).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    if blocking:
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / iters * 1e6  # us
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us
