"""Theorem 1 — empirical regret of E3CS vs the analytic bound, across
horizons and fairness quotas, on iid and adversarially shifting sequences.
Also compares the two samplers (Plackett-Luce vs Madow systematic)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.selection import regret, theorem1_bound, theorem1_eta
from repro.core.sim import selection_sim

from .common import QUICK, emit, save_json


def _xs_shift(T, K, seed=0):
    """Adversarial shift: reliable and unreliable halves swap at T/2."""
    rng = np.random.default_rng(seed)
    r1 = np.concatenate([np.full(K // 2, 0.9), np.full(K - K // 2, 0.1)])
    r2 = r1[::-1]
    return np.stack([rng.binomial(1, r1 if t < T // 2 else r2) for t in range(T)]).astype(np.float32)


def run():
    K, k = 50, 10
    horizons = [200, 400] if QUICK else [200, 400, 1000, 2500]
    out = {}
    for T in horizons:
        for frac in (0.0, 0.5):
            sigmas = np.full(T, frac * k / K)
            eta = theorem1_eta(K, k, sigmas)
            for env, xs in (("bern", None), ("shift", _xs_shift(T, K))):
                t0 = time.perf_counter()
                sim = selection_sim(
                    "e3cs", K=K, k=k, T=T, frac=frac, eta=eta, xs_override=xs, seed=1
                )
                us = (time.perf_counter() - t0) / T * 1e6
                R = regret(sim["ps"], sim["xs"], k, sigmas, mode="static")
                bound = theorem1_bound(K, k, sigmas, eta)
                key = f"T{T}_sig{frac}_{env}"
                out[key] = {"regret": R, "bound": bound, "ratio": R / bound, "eta": eta}
                emit(f"regret/{key}", us, f"R={R:.1f};bound={bound:.1f};ratio={R/bound:.3f}")
                assert R <= bound, f"Theorem 1 violated: {key}: {R} > {bound}"
    # sampler comparison at fixed setting
    T = 400
    sigmas = np.full(T, 0.25 * k / K)
    eta = theorem1_eta(K, k, sigmas)
    for sampler in ("plackett_luce", "systematic"):
        sim = selection_sim("e3cs", K=K, k=k, T=T, frac=0.25, eta=eta, sampler=sampler, seed=2)
        R = regret(sim["ps"], sim["xs"], k, sigmas, mode="static")
        out[f"sampler_{sampler}"] = {"regret": R, "cep": float((sim["masks"] * sim["xs"]).sum())}
        emit(f"regret/sampler_{sampler}", 0.0, f"R={R:.1f};cep={out[f'sampler_{sampler}']['cep']:.0f}")
    save_json("regret", out)
    return out


if __name__ == "__main__":
    run()
