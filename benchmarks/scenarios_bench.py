"""Scenario-suite benchmark: bit-packed replay at scale + the selector x
scenario evaluation grid.

Rows (name,us_per_call,derived):
  scenarios/replay/K=...       — e3cs whole-horizon scan fed by the packed
                                 uint8 trace; derived carries packed vs dense
                                 MB, rounds/sec, record time, and (at K where
                                 the dense trace fits) bit-identity vs the
                                 unpacked xs_override path
  scenarios/grid/<sc>/<sel>    — one compiled run per cell; derived carries
                                 CEP / effective participation / Jain
  scenarios/multi_job/J=...    — the scenario axis on the batched engine:
                                 one dispatch per round serves every scenario

CLI:  python benchmarks/scenarios_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .common import emit, reporter
except ImportError:  # running as a script: python benchmarks/scenarios_bench.py
    from common import emit, reporter

from repro.obs.paths import artifact_path

from repro.configs.base import FLConfig
from repro.core.volatility import make_volatility
from repro.engine.scan_sim import build_scan_runner, scan_selection_sim
from repro.scenarios import (
    format_grid,
    make_scenario,
    packed_nbytes,
    record_trace,
    run_grid,
    run_grid_multi_job,
    unpack_trace,
)

GRID_SCENARIOS = ("paper_iid", "markov_sticky", "diurnal", "regional_outage", "flash_crowd")
GRID_SELECTORS = ("e3cs", "random", "fedcs")


def bench_replay(K_list, T: int, out: dict):
    rows = {}
    for K in K_list:
        k = max(1, K // 50)
        vol, rho = make_scenario("regional_outage", K, T, seed=0)
        t0 = time.perf_counter()
        packed = record_trace(vol, T, seed=0, chunk=min(64, T))
        record_s = time.perf_counter() - t0
        packed_mb = packed.nbytes / 1e6
        dense_mb = T * K * 4 / 1e6
        # hold one compiled runner so steady-state timing excludes compilation
        fl = FLConfig(K=K, k=k, rounds=T, scheme="e3cs", quota="const", quota_frac=0.5)
        runner, state0 = build_scan_runner(fl, make_volatility("bernoulli", rho), rho, override="packed")
        key = jax.random.PRNGKey(0)
        xs_in = jnp.asarray(packed)
        jax.block_until_ready(runner(state0, key, xs_in)[1])  # compile
        t0 = time.perf_counter()
        masks_packed = runner(state0, key, xs_in)[1]
        jax.block_until_ready(masks_packed)
        packed_s = time.perf_counter() - t0
        # lean outputs: per-round scalars only, the full-horizon mode at K=1e6
        # (full outputs would add ~T*K*4 bytes per emitted array)
        lean_runner, lean_state0 = build_scan_runner(
            fl, make_volatility("bernoulli", rho), rho, override="packed", outputs="lean"
        )
        jax.block_until_ready(lean_runner(lean_state0, key, xs_in)[1])  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(lean_runner(lean_state0, key, xs_in)[1])
        lean_s = time.perf_counter() - t0
        derived = (
            f"T={T};packed_mb={packed_mb:.1f};dense_mb={dense_mb:.1f}"
            f";rounds_per_s={T / packed_s:.1f};lean_rounds_per_s={T / lean_s:.1f};record_s={record_s:.2f}"
        )
        bitident = None
        if dense_mb <= 200:  # materialise the dense trace only where it is cheap
            a = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, rho=rho, packed_override=packed)
            b = scan_selection_sim("e3cs", K=K, k=k, T=T, frac=0.5, rho=rho, xs_override=unpack_trace(packed, K))
            bitident = bool(np.array_equal(a["masks"], b["masks"]) and np.array_equal(a["xs"], b["xs"]))
            derived += f";bitident_vs_dense={bitident}"
        rows[K] = {
            "T": T, "k": k, "packed_mb": packed_mb, "dense_mb": dense_mb,
            "record_s": record_s, "packed_s": packed_s, "rounds_per_s": T / packed_s,
            "lean_s": lean_s, "lean_rounds_per_s": T / lean_s,
            "bitident_vs_dense": bitident,
        }
        emit(f"scenarios/replay/K={K}", packed_s / T * 1e6, derived)
        # full-horizon footprint at this K, the number the subsystem exists for
        full_mb = packed_nbytes(2500, K) / 1e6
        rows[K]["packed_mb_T2500"] = full_mb
    out["replay"] = rows
    return rows


def bench_grid(K: int, T: int, out: dict, rep=None):
    t0 = time.perf_counter()
    rows = run_grid(GRID_SELECTORS, GRID_SCENARIOS, K=K, k=max(1, K // 5), T=T, seed=0, log=rep)
    total_s = time.perf_counter() - t0
    for r in rows:
        emit(
            f"scenarios/grid/{r['scenario']}/{r['selector']}",
            total_s / len(rows) * 1e6,
            f"cep={r['cep']:.0f};eff={r['eff_participation']:.3f};jain={r['jain']:.3f}",
        )
    print(format_grid(rows), file=sys.stderr)
    out["grid"] = {"K": K, "T": T, "total_s": total_s, "rows": rows}
    return rows


def bench_multi_job(K: int, T: int, out: dict):
    scenarios = list(GRID_SCENARIOS)
    t0 = time.perf_counter()
    rows = run_grid_multi_job(scenarios, K=K, k=max(1, K // 5), T=T, seed=0)
    total_s = time.perf_counter() - t0
    per_round_us = total_s / T * 1e6
    emit(
        f"scenarios/multi_job/J={len(scenarios)}",
        per_round_us,
        f"K={K};T={T};per_cell_round_us={per_round_us / len(scenarios):.1f}",
    )
    out["multi_job"] = {"J": len(scenarios), "K": K, "T": T, "total_s": total_s, "rows": rows}
    return rows


def run_late_credit(K: int = 100, T: int = 1000, staleness: int = 2, alpha: float = 0.5):
    """The late-credit feedback experiment: deadline vs late-credit E3CS
    feedback on the selector x scenario grid (same randomness per cell, so
    every delta is the policy), written to ``late_credit_grid.*`` under the
    results root (``repro.obs.paths`` — ``REPRO_RESULTS`` relocates it).

    ``python benchmarks/scenarios_bench.py --late-credit`` regenerates the
    committed artifact.
    """
    import json

    config = {"K": K, "T": T, "k": max(1, K // 5), "staleness": staleness, "alpha": alpha, "seed": 0}
    rep = reporter("late_credit", config=config)
    t0 = time.perf_counter()
    rows = run_grid(
        GRID_SELECTORS, GRID_SCENARIOS, K=K, k=max(1, K // 5), T=T, seed=0,
        staleness=staleness, alpha=alpha, feedback="late_credit", log=rep,
    )
    total_s = time.perf_counter() - t0
    table = format_grid(rows)
    print(table, file=sys.stderr)
    for r in rows:
        if "lc_cep" in r:
            emit(
                f"scenarios/late_credit/{r['scenario']}/{r['selector']}",
                total_s / len(rows) * 1e6,
                f"acep={r['async_cep']:.0f};lc_cep={r['lc_cep']:.0f};lc_drift={r['lc_drift']:.2e}",
            )
    meta = {
        **config,
        "feedback": "late_credit vs deadline",
        "command": "python benchmarks/scenarios_bench.py --late-credit",
        "rows": rows,
    }
    rep.save({"total_s": total_s, **config})
    with open(artifact_path("late_credit_grid.json"), "w") as f:
        json.dump(meta, f, indent=1, default=float)
    with open(artifact_path("late_credit_grid.txt"), "w") as f:
        f.write(
            f"# late-credit feedback experiment: K={K} k={max(1, K // 5)} T={T} "
            f"S={staleness} alpha={alpha} seed=0\n"
            "# acep/aeff/a_jain = staleness-aware CEP / eff. participation / Jain\n"
            "# fairness under deadline feedback; lc_* = the same under late-credit\n"
            "# feedback (buffered selection-round p, decayed alpha**lag reward) —\n"
            "# compare lc_jain against a_jain, NOT the sync jain column;\n"
            "# lc_drift = max |dlogw| of the final E3CS state between the policies.\n"
            + table + "\n"
        )
    return rows


def run(smoke: bool = False):
    out = {}
    rep = reporter("scenarios", config={"smoke": smoke})
    if smoke:
        bench_replay([10_000], T=32, out=out)
        bench_grid(K=64, T=200, out=out, rep=rep)
        bench_multi_job(K=64, T=60, out=out)
    else:
        bench_replay([100_000, 1_000_000], T=64, out=out)
        bench_grid(K=100, T=1000, out=out, rep=rep)
        bench_multi_job(K=100, T=300, out=out)
    rep.save(out)
    rep = out["replay"]
    if any(r["bitident_vs_dense"] is False for r in rep.values()):
        print("scenarios,0,WARN:packed_replay_not_bit_identical", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced CPU/CI protocol")
    ap.add_argument("--late-credit", action="store_true",
                    help="run the deadline-vs-late-credit feedback sweep and write results/late_credit_grid.*")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.late_credit:
        run_late_credit()
    else:
        run(smoke=args.smoke)


if __name__ == "__main__":
    sys.path.insert(0, ".")
    main()
