"""Kernel benchmarks: staged vs fused round engine, tuned vs default tiles.

Two surfaces:

* **engine curves** — the whole compiled E3CS horizon (allocate -> perturb ->
  top-k -> update) timed staged vs ``fused=True`` at fleet sizes.  The
  ``*_rounds_per_s`` leaves gate in CI (``scripts/check_bench.py``);
  ``fused_speedup_x`` is informational only (a ratio of two noisy
  measurements — excluded from the gate by name).  On CPU both paths
  dispatch to the jnp references (``repro.kernels.dispatch``), so the CPU
  speedup reflects fusing the reference composition under one jit, not VMEM
  residency — the TPU run is where the Pallas fusion shows.  Honest numbers
  either way.
* **tuned vs default** — the ops-level dispatch timed with the autotune
  cache consulted (``tile=None``) against the hardcoded default tile.  The
  cache state rides along in the JSON: cold lookups mean the "tuned" column
  actually ran the defaults, and ``check_bench`` surfaces that as a note
  instead of gating on it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops
from repro.obs.paths import autotune_path

from .common import QUICK, emit, save_json, time_fn


def _engine_runner(K: int, T: int, fused: bool):
    from repro.configs.base import FLConfig
    from repro.core.volatility import BernoulliVolatility, paper_success_rates
    from repro.engine.scan_sim import build_scan_runner

    rho = paper_success_rates(K)
    vol = BernoulliVolatility(jnp.asarray(rho))
    fl = FLConfig(
        K=K, k=max(16, K // 1000), rounds=T, scheme="e3cs", quota_frac=0.5, allocator="bisect"
    )
    return build_scan_runner(fl, vol, rho, outputs="lean", fused=fused)


def bench_engine_curves(K_list, T: int, iters: int, out: dict):
    """Staged vs fused rounds/s over the whole compiled horizon."""
    key = jax.random.PRNGKey(0)
    for K in K_list:
        xs = jnp.zeros((T, 0), jnp.float32)
        row = {"K": K, "T": T}
        for name, fused in (("staged", False), ("fused", True)):
            run_fn, s0 = _engine_runner(K, T, fused)
            us = time_fn(lambda r=run_fn, s=s0: r(s, key, xs), iters=iters, warmup=1)
            row[f"{name}_rounds_per_s"] = round(T * 1e6 / us, 2)
            row[f"{name}_us_per_round"] = round(us / T, 1)
        row["fused_speedup_x"] = round(row["fused_rounds_per_s"] / row["staged_rounds_per_s"], 3)
        out[f"engine_K{K}"] = row
        emit(
            f"kernel/round_fused/K={K}",
            row["fused_us_per_round"],
            f"staged_rps={row['staged_rounds_per_s']};fused_rps={row['fused_rounds_per_s']}"
            f";speedup={row['fused_speedup_x']}x",
        )


def bench_tuned_vs_default(K: int, out: dict):
    """The dispatch path with ``tile=None`` (autotune cache) vs the
    hardcoded default tile, on whatever route this backend picks."""
    rng = np.random.default_rng(0)
    kk = max(16, K // 100)
    p = jnp.asarray(rng.gamma(1.0, 1.0, K), jnp.float32)
    p = p / p.sum() * kk
    key = jax.random.PRNGKey(1)
    default_tile = autotune.DEFAULTS["gumbel_topk"]["tile"]
    us_def = time_fn(lambda: ops.gumbel_topk_sample(key, p, kk, tile=default_tile), iters=3, warmup=1)
    us_tuned = time_fn(lambda: ops.gumbel_topk_sample(key, p, kk), iters=3, warmup=1)
    tuned = autotune.best_config("gumbel_topk", K)
    out["tuned_vs_default"] = {
        "kernel": "gumbel_topk", "K": K, "k": kk,
        "default_tile": default_tile, "tuned_tile": tuned["tile"],
        "us_default": round(us_def, 1), "us_tuned": round(us_tuned, 1),
        "tuned_speedup_x": round(us_def / us_tuned, 3),
    }
    emit(
        f"kernel/tuned_vs_default/K={K}",
        us_tuned,
        f"tile={tuned['tile']}v{default_tile};default_us={us_def:.0f};delta={us_def / us_tuned:.3f}x",
    )


def run(smoke: bool | None = None):
    smoke = QUICK if smoke is None else smoke
    autotune.reset_cold()
    out = {"backend": jax.default_backend(), "smoke": bool(smoke)}

    T = 60 if smoke else 100
    K_list = [10_000] if smoke else [100_000, 1_000_000, 10_000_000]
    bench_engine_curves(K_list, T, iters=2 if smoke else 3, out=out)
    bench_tuned_vs_default(10_000 if smoke else 100_000, out)

    cold = autotune.cold_keys()
    out["autotune"] = {"path": autotune_path(), "cold": bool(cold), "cold_keys": cold}
    if cold:
        emit("kernel/autotune", 0.0, f"COLD_CACHE:{len(cold)}_key(s)_ran_defaults")
    save_json("kernels", out)
    return out


if __name__ == "__main__":
    run()
