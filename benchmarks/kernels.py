"""Kernel microbenchmarks: Pallas (interpret-mode on CPU) vs jnp oracle.

Interpret-mode wall time is NOT TPU performance — the derived column records
the correctness deltas and the arithmetic intensity each kernel targets; the
roofline benchmark covers the deployment-scale analysis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import QUICK, emit, save_json, time_fn


def run():
    rng = np.random.default_rng(0)
    out = {}

    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    o_ref = ref.flash_attention_ref(q, k, v)
    o = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    err = float(jnp.abs(o - o_ref).max())
    us_k = time_fn(lambda: ops.flash_attention(q, k, v, block_q=64, block_k=64), iters=3, warmup=1)
    us_r = time_fn(lambda: ref.flash_attention_ref(q, k, v), iters=3, warmup=1)
    out["flash_attention"] = {"max_err": err, "us_interpret": us_k, "us_ref": us_r}
    emit("kernel/flash_attention", us_k, f"err={err:.1e};ref_us={us_r:.0f}")

    b, S2, H2, P, G, N = 1, 256, 4, 32, 2, 64
    x = jnp.asarray(rng.normal(size=(b, S2, H2, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, S2, H2)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (H2,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, S2, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, S2, G, N)), jnp.float32)
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
    y_ref, st_ref = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    err = float(jnp.abs(y - y_ref).max())
    us_k = time_fn(lambda: ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64), iters=3, warmup=1)
    us_r = time_fn(lambda: ref.ssd_scan_ref(x, dt, A, Bm, Cm), iters=3, warmup=1)
    out["ssd_scan"] = {"max_err": err, "us_interpret": us_k, "us_ref": us_r}
    emit("kernel/ssd_scan", us_k, f"err={err:.1e};ref_us={us_r:.0f}")

    K = 4096 if QUICK else 1 << 20
    p = jnp.asarray(rng.gamma(1, 1, K), jnp.float32)
    p = p / p.sum() * 20
    idx = ops.gumbel_topk_sample(jax.random.PRNGKey(0), p, 20, tile=1024)
    us_k = time_fn(lambda: ops.gumbel_topk_sample(jax.random.PRNGKey(0), p, 20, tile=1024), iters=3, warmup=1)
    out["gumbel_topk"] = {"K": K, "us_interpret": us_k, "n_unique": len(set(np.asarray(idx).tolist()))}
    emit("kernel/gumbel_topk", us_k, f"K={K};unique={out['gumbel_topk']['n_unique']}")

    save_json("kernels", out)
    return out


if __name__ == "__main__":
    run()
