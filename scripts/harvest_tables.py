"""Harvest table_training rows from the bench log into the cached JSON."""
import json, os, re
rows = {}
for line in open('results/bench_tables.log'):
    m = re.match(r"table_(\w+)_(\w+)_(\w+)/([\w.\-]+),([\d.]+),final=([\d.]+);cep=(\d+);r2a=(.*)", line.strip())
    if not m: continue
    task, dist, upd, scheme, us, final, cep, r2a = m.groups()
    rows.setdefault(task, {}).setdefault(f"{dist}_{upd}", {})[scheme] = {
        "final_acc": float(final), "cep": float(cep),
        "rounds_to": eval(r2a), "wall_s": float(us)*60/1e6, "acc_curve": [],
    }
os.makedirs('results/bench', exist_ok=True)
json.dump(rows, open('results/bench/BENCH_table_training.json','w'), indent=1)
print({t: {g: list(v) for g, v in d.items()} for t, d in rows.items()})
