"""Harvest table_training rows from the bench log into the cached JSON.

Reads ``<results>/bench_tables.log`` and writes ``BENCH_table_training.json``
through the unified reporter (``repro.obs``), so the artifact lands in the
same layout as every other bench (``REPRO_RESULTS`` / ``REPRO_BENCH_OUT``
relocate it) and gains a paired JSONL run log.
"""
import ast
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import Reporter
from repro.obs.paths import artifact_path

rows = {}
for line in open(artifact_path("bench_tables.log")):
    m = re.match(r"table_(\w+)_(\w+)_(\w+)/([\w.\-]+),([\d.]+),final=([\d.]+);cep=(\d+);r2a=(.*)", line.strip())
    if not m:
        continue
    task, dist, upd, scheme, us, final, cep, r2a = m.groups()
    rows.setdefault(task, {}).setdefault(f"{dist}_{upd}", {})[scheme] = {
        "final_acc": float(final), "cep": float(cep),
        "rounds_to": ast.literal_eval(r2a), "wall_s": float(us) * 60 / 1e6, "acc_curve": [],
    }
Reporter("table_training").save(rows)
print({t: {g: list(v) for g, v in d.items()} for t, d in rows.items()})
