#!/usr/bin/env python
"""Run-log explorer: render JSONL run logs into the text/CSV reports CI
uploads (and humans actually read).

Usage:
    python scripts/obs_explore.py summarize <log.jsonl | dir> [...] [-o OUT]
    python scripts/obs_explore.py fairness  <log.jsonl | dir> [...] [--csv] [-o OUT]
    python scripts/obs_explore.py diff      <A.jsonl | dirA> <B.jsonl | dirB>
                                            [--tolerance 0.05] [--strict] [-o OUT]

* ``summarize`` — one screen per log: header, event counts, alert listing,
  metric-stream overview (first/last window p50 per metric) and the final
  summary scalars.
* ``fairness`` — the client-axis fairness telemetry (Jain / Gini /
  top-decile share / region CEP skew — the ``fairness`` tap group) as a
  window-by-window table, or ``--csv`` rows
  (``run,stream,metric,window,p50``) for spreadsheets.
* ``diff`` — pair two runs (or two directories of runs, matched by the
  header ``run`` name, falling back to the filename stem) and compare every
  shared metric stream window by window under its declared gate direction;
  new/disappeared alerts are listed.  Exits 0 unless ``--strict`` and a
  gated direction regressed beyond ``--tolerance`` — the PR CI step runs it
  informationally against the committed baseline log.

Directories are scanned non-recursively for ``*.jsonl`` (a ``baseline/``
subdirectory therefore never collides with the fresh logs above it).
Reads every supported run-log schema (v1 logs simply have no alerts or
timestamps).  Stdlib-only on purpose: usable in CI steps and on laptops
without the jax stack installed.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

FAIRNESS_METRICS = ("jain", "gini", "top_decile_share", "region_cep_skew")


# ---------------------------------------------------------------------------
# loading
# ---------------------------------------------------------------------------


def read_records(path: str) -> List[dict]:
    records = []
    with open(path) as fh:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i + 1}: invalid JSON ({e})")
    return records


def expand_paths(paths: List[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(sorted(glob.glob(os.path.join(p, "*.jsonl"))))
        else:
            out.append(p)
    return out


def run_name(records: List[dict], path: str) -> str:
    for r in records:
        if r.get("event") == "header":
            return str(r.get("run") or r.get("name") or "")
    return os.path.splitext(os.path.basename(path))[0]


def metric_streams(records: List[dict]) -> Dict[str, dict]:
    """stream name -> {"better": {...}, "p50": {metric: [...]}, "window": W}."""
    out: Dict[str, dict] = {}
    for r in records:
        if r.get("event") != "metrics":
            continue
        w = r.get("windows") or {}
        aggs = w.get("aggs") or {}
        out[str(r.get("stream"))] = {
            "better": r.get("better") or {},
            "window": w.get("window"),
            "n_windows": w.get("n_windows"),
            "p50": {m: (cell or {}).get("p50") or [] for m, cell in aggs.items()},
        }
    return out


def alerts_of(records: List[dict]) -> List[dict]:
    return [r for r in records if r.get("event") == "alert"]


def summary_of(records: List[dict]) -> dict:
    for r in reversed(records):
        if r.get("event") == "summary":
            return r.get("data") or {}
    return {}


def _fmt(v) -> str:
    if v is None:
        return "null"
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------


def cmd_summarize(args) -> Tuple[int, List[str]]:
    lines: List[str] = []
    for path in expand_paths(args.logs):
        records = read_records(path)
        name = run_name(records, path)
        counts: Dict[str, int] = {}
        for r in records:
            counts[str(r.get("event"))] = counts.get(str(r.get("event")), 0) + 1
        lines.append(f"== {name} ({path})")
        lines.append("   events: " + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())))
        for a in alerts_of(records):
            lines.append(
                f"   ALERT [{a.get('severity')}] {a.get('rule')}: "
                f"{a.get('message') or json.dumps(a.get('detail'))}"
            )
        for stream, st in metric_streams(records).items():
            for metric, p50 in st["p50"].items():
                better = st["better"].get(metric, "none")
                if p50:
                    lines.append(
                        f"   {stream}.{metric} [{better}] windows={len(p50)} "
                        f"p50 first={_fmt(p50[0])} last={_fmt(p50[-1])}"
                    )
        summ = summary_of(records)
        if summ:
            scalars = {k: v for k, v in summ.items() if isinstance(v, (int, float, str))}
            lines.append("   summary: " + ", ".join(f"{k}={_fmt(v)}" for k, v in sorted(scalars.items())))
        lines.append("")
    return 0, lines


def cmd_fairness(args) -> Tuple[int, List[str]]:
    lines: List[str] = []
    if args.csv:
        lines.append("run,stream,metric,window,p50")
    found = False
    for path in expand_paths(args.logs):
        records = read_records(path)
        name = run_name(records, path)
        for stream, st in metric_streams(records).items():
            fair = {m: v for m, v in st["p50"].items() if m in FAIRNESS_METRICS}
            if not fair:
                continue
            found = True
            if args.csv:
                for metric, p50 in fair.items():
                    for w, v in enumerate(p50):
                        lines.append(f"{name},{stream},{metric},{w},{_fmt(v)}")
            else:
                lines.append(f"== {name} / {stream}")
                for metric, p50 in fair.items():
                    better = st["better"].get(metric, "none")
                    vals = " ".join(_fmt(v) for v in p50)
                    lines.append(f"   {metric:<18} [{better:>6}] {vals}")
                lines.append("")
    if not found and not args.csv:
        lines.append("no fairness streams found (run with sketches enabled to emit them)")
    return 0, lines


def _pair_runs(a_paths: List[str], b_paths: List[str]):
    def index(paths):
        idx = {}
        for p in paths:
            recs = read_records(p)
            idx[run_name(recs, p)] = (p, recs)
        return idx

    A, B = index(a_paths), index(b_paths)
    shared = [n for n in A if n in B]
    only_a = [n for n in A if n not in B]
    only_b = [n for n in B if n not in A]
    return [(n, A[n], B[n]) for n in shared], only_a, only_b


def cmd_diff(args) -> Tuple[int, List[str]]:
    a_paths = expand_paths([args.a])
    b_paths = expand_paths([args.b])
    pairs, only_a, only_b = _pair_runs(a_paths, b_paths)
    lines: List[str] = [f"diff: A={args.a}  B={args.b}  tolerance={args.tolerance:.0%}"]
    regressions = 0
    for name in only_a:
        lines.append(f"  only in A: {name}")
    for name in only_b:
        lines.append(f"  only in B: {name}")
    for name, (pa, ra), (pb, rb) in pairs:
        lines.append(f"== {name}")
        sa, sb = metric_streams(ra), metric_streams(rb)
        for stream in sorted(set(sa) | set(sb)):
            if stream not in sa or stream not in sb:
                lines.append(f"   {stream}: only in {'A' if stream in sa else 'B'}")
                continue
            better = {**sa[stream]["better"], **sb[stream]["better"]}
            for metric in sorted(set(sa[stream]["p50"]) | set(sb[stream]["p50"])):
                pa50 = sa[stream]["p50"].get(metric) or []
                pb50 = sb[stream]["p50"].get(metric) or []
                if not pa50 or not pb50:
                    lines.append(f"   {stream}.{metric}: only in {'A' if pa50 else 'B'}")
                    continue
                if len(pa50) != len(pb50):
                    lines.append(
                        f"   {stream}.{metric}: window count {len(pa50)} -> {len(pb50)} (skipped)"
                    )
                    continue
                direction = better.get(metric, "none")
                worst = None
                for w, (va, vb) in enumerate(zip(pa50, pb50)):
                    if not isinstance(va, (int, float)) or not isinstance(vb, (int, float)):
                        continue
                    delta = vb - va
                    rel = delta / abs(va) if va else 0.0
                    bad = (
                        (direction == "higher" and rel < -args.tolerance)
                        or (direction == "lower" and rel > args.tolerance)
                        or (direction == "equal" and abs(rel) > 1e-9)
                    )
                    if worst is None or abs(rel) > abs(worst[1]):
                        worst = (w, rel, va, vb, bad)
                if worst is None:
                    continue
                w, rel, va, vb, bad = worst
                mark = " REGRESSED" if bad else ""
                if bad:
                    regressions += 1
                lines.append(
                    f"   {stream}.{metric} [{direction}] worst window {w}: "
                    f"{_fmt(va)} -> {_fmt(vb)} ({rel:+.1%}){mark}"
                )
        aa = {json.dumps((a.get("rule"), a.get("severity"))) for a in alerts_of(ra)}
        for a in alerts_of(rb):
            tag = json.dumps((a.get("rule"), a.get("severity")))
            star = "NEW " if tag not in aa else ""
            lines.append(
                f"   {star}ALERT [{a.get('severity')}] {a.get('rule')}: "
                f"{a.get('message') or json.dumps(a.get('detail'))}"
            )
        lines.append("")
    if not pairs:
        lines.append("no runs in common (nothing to diff)")
    lines.append(f"{regressions} gated regression(s)")
    return (1 if args.strict and regressions else 0), lines


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-log overview: events, alerts, streams, summary")
    p.add_argument("logs", nargs="+")
    p.add_argument("-o", "--out", default=None, help="write the report here as well as stdout")

    p = sub.add_parser("fairness", help="fairness telemetry as a table or CSV")
    p.add_argument("logs", nargs="+")
    p.add_argument("--csv", action="store_true")
    p.add_argument("-o", "--out", default=None)

    p = sub.add_parser("diff", help="window-by-window comparison of two runs / run dirs")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--tolerance", type=float, default=0.05)
    p.add_argument("--strict", action="store_true", help="exit 1 on gated regressions")
    p.add_argument("-o", "--out", default=None)

    args = ap.parse_args(argv)
    rc, lines = {"summarize": cmd_summarize, "fairness": cmd_fairness, "diff": cmd_diff}[args.cmd](args)
    text = "\n".join(lines) + "\n"
    sys.stdout.write(text)
    if args.out:
        d = os.path.dirname(args.out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.out, "w") as fh:
            fh.write(text)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
