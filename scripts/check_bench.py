#!/usr/bin/env python
"""Bench regression gate: compare the smoke ``BENCH_*.json`` results against a
baseline and fail on throughput regressions.

Usage:
    python scripts/check_bench.py [--results results/bench]
                                  [--baseline results/bench/baseline]
                                  [--tolerance 0.30] [--soft] [--update]

For every ``BENCH_<name>.json`` present in both trees, every numeric leaf
whose key looks like a throughput (``*_per_s``, ``ticks_per_s``, ``speedup*``)
is compared at its dotted path; the gate fails (exit 1) when
``new < baseline * (1 - tolerance)`` for any of them.  Latency-like keys are
deliberately ignored — only "bigger is better" metrics gate.

* ``--update`` copies the current results over the baseline (CI does this on
  pushes to main, then saves the baseline to the actions cache; the committed
  ``results/bench/baseline/`` seeds the very first comparison).
* ``--soft`` reports regressions but exits 0 — used when the baseline came
  from a different machine (the committed seed) rather than the CI cache, so
  hardware deltas don't fail PRs.
* env ``BENCH_GATE_TOL`` overrides the default 30% tolerance.

Files without a baseline counterpart are skipped with a note, so adding a new
benchmark never fails the gate before its first baseline lands on main.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

THROUGHPUT_KEYS = ("_per_s", "ticks_per_s", "rounds_per_s")
# speedup_* ratios compound the noise of two measurements, and the .host.
# reference timings inside the async serve report are a baseline for the
# compiled path, not a gated product — both flap on shared CI runners
EXCLUDE_PATH_PARTS = (".host.", "speedup")


def is_throughput_key(key: str) -> bool:
    return any(pat in key for pat in THROUGHPUT_KEYS)


def numeric_leaves(obj, prefix=""):
    """Yield (dotted_path, value) for numeric leaves under throughput keys."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from numeric_leaves(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        if is_throughput_key(prefix.rsplit(".", 1)[-1]) and not any(p in prefix for p in EXCLUDE_PATH_PARTS):
            yield prefix, float(obj)


def compare_file(name: str, new_path: str, base_path: str, tol: float):
    with open(new_path) as f:
        new = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    new_leaves = dict(numeric_leaves(new))
    regressions, improvements, checked = [], [], 0
    for path, base_v in numeric_leaves(base):
        if path not in new_leaves or base_v <= 0:
            continue
        checked += 1
        new_v = new_leaves[path]
        ratio = new_v / base_v
        if new_v < base_v * (1.0 - tol):
            regressions.append((path, base_v, new_v, ratio))
        elif ratio > 1.0 + tol:
            improvements.append((path, base_v, new_v, ratio))
    return checked, regressions, improvements


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=os.environ.get("REPRO_BENCH_OUT", "results/bench"))
    ap.add_argument("--baseline", default=None, help="default: <results>/baseline")
    ap.add_argument("--tolerance", type=float, default=float(os.environ.get("BENCH_GATE_TOL", "0.30")))
    ap.add_argument("--soft", action="store_true", help="report regressions but exit 0")
    ap.add_argument("--update", action="store_true", help="copy current results over the baseline")
    args = ap.parse_args()
    baseline = args.baseline or os.path.join(args.results, "baseline")

    names = sorted(
        f for f in (os.listdir(args.results) if os.path.isdir(args.results) else [])
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print(f"check_bench: no BENCH_*.json under {args.results}; nothing to do")
        return 0

    if args.update:
        os.makedirs(baseline, exist_ok=True)
        for f in names:
            shutil.copy2(os.path.join(args.results, f), os.path.join(baseline, f))
        print(f"check_bench: baseline updated with {len(names)} file(s): {', '.join(names)}")
        return 0

    any_regression = False
    for f in names:
        base_path = os.path.join(baseline, f)
        if not os.path.exists(base_path):
            print(f"check_bench: {f}: no baseline yet, skipping")
            continue
        checked, regs, imps = compare_file(f, os.path.join(args.results, f), base_path, args.tolerance)
        status = "OK" if not regs else "REGRESSION"
        print(f"check_bench: {f}: {checked} metric(s) checked, {status}")
        for path, b, n, r in regs:
            any_regression = True
            print(f"  REGRESSION {path}: {b:.1f} -> {n:.1f} ({r:.2f}x, tolerance {1 - args.tolerance:.2f}x)")
        for path, b, n, r in imps:
            print(f"  improved   {path}: {b:.1f} -> {n:.1f} ({r:.2f}x)")

    if any_regression and args.soft:
        print("check_bench: regressions found, but --soft set (cross-machine baseline) — not failing")
        return 0
    if any_regression:
        print(f"check_bench: FAILED — throughput regressed by more than {args.tolerance:.0%}")
        return 1
    print("check_bench: all gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
