#!/usr/bin/env python
"""Bench regression gate: compare the smoke ``BENCH_*.json`` results against a
baseline and fail on regressions — scalar throughput leaves AND windowed
metric streams.

Usage:
    python scripts/check_bench.py [--results results/bench]
                                  [--baseline results/bench/baseline]
                                  [--tolerance 0.30] [--soft] [--update]
                                  [--metrics-only]

Two gate surfaces per ``BENCH_<name>.json`` present in both trees:

* **scalar leaves** — every numeric leaf whose key looks like a throughput
  (``*_per_s``, ``ticks_per_s``, ``speedup*``) is compared at its dotted
  path; the gate fails (exit 1) when ``new < baseline * (1 - tolerance)``.
  Latency-like keys are deliberately ignored — only "bigger is better"
  metrics gate.  Zero/negative baseline values, non-finite values (json
  ``NaN``/``Infinity`` or ``null``) and keys present in only one tree are
  skipped with a note instead of dividing by zero or raising.
* **windowed metric streams** — the ``"metrics"`` block the unified
  reporter writes (``repro.obs``): for each stream, each metric's
  per-window ``p50`` array is compared window by window under the stream's
  declared gate direction: ``"higher"`` fails when a window drops below
  ``base * (1 - tol)``, ``"lower"`` when it rises above ``base * (1 +
  tol)``, ``"equal"`` when it differs at all (beyond 1e-9 relative), and
  ``"none"`` is reported but never gates.  Window-count mismatches (e.g. a
  protocol change) are reported and skipped, not failed.

* ``--update`` copies the current results over the baseline (CI does this on
  pushes to main, then saves the baseline to the actions cache; the committed
  ``results/bench/baseline/`` seeds the very first comparison).
* ``--soft`` reports regressions but exits 0 — used when the baseline came
  from a different machine (the committed seed) rather than the CI cache, so
  hardware deltas don't fail PRs.
* ``--metrics-only`` gates/prints only the windowed metric streams — the PR
  metrics-diff step uses it for a per-window regression summary.
* ``--streams <regex>`` restricts the metrics gate to stream names matching
  the pattern — the CI fairness step runs ``--metrics-only --streams
  fairness`` so the windowed fairness series (Jain / Gini / top-decile
  share, emitted by sketch-enabled benchmarks) gate on their own line.
* env ``BENCH_GATE_TOL`` overrides the default 30% tolerance.

Files without a baseline counterpart are skipped with a note, so adding a new
benchmark never fails the gate before its first baseline lands on main.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import re
import shutil
import sys

THROUGHPUT_KEYS = ("_per_s", "ticks_per_s", "rounds_per_s")
# speedup_* ratios compound the noise of two measurements, and the .host.
# reference timings inside the async serve report are a baseline for the
# compiled path, not a gated product — both flap on shared CI runners
EXCLUDE_PATH_PARTS = (".host.", "speedup")
EQUAL_RTOL = 1e-9


def is_throughput_key(key: str) -> bool:
    return any(pat in key for pat in THROUGHPUT_KEYS)


def _finite_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def numeric_leaves(obj, prefix=""):
    """Yield (dotted_path, value) for numeric leaves under throughput keys;
    the reporter's ``metrics`` block is gated separately, not as leaves."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            if not prefix and k == "metrics":
                continue
            yield from numeric_leaves(v, f"{prefix}.{k}" if prefix else str(k))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        if is_throughput_key(prefix.rsplit(".", 1)[-1]) and not any(p in prefix for p in EXCLUDE_PATH_PARTS):
            yield prefix, obj


def compare_scalars(new: dict, base: dict, tol: float):
    """Gate throughput leaves; returns (checked, regressions, improvements,
    notes).  Never divides by a zero baseline, never gates non-finite values,
    and names one-sided keys instead of silently dropping them."""
    new_leaves = dict(numeric_leaves(new))
    base_leaves = dict(numeric_leaves(base))
    regressions, improvements, notes = [], [], []
    checked = 0
    for path in sorted(set(base_leaves) | set(new_leaves)):
        if path not in new_leaves:
            notes.append(f"{path}: in baseline only (removed?) — skipped")
            continue
        if path not in base_leaves:
            notes.append(f"{path}: new metric, no baseline — skipped")
            continue
        base_v, new_v = base_leaves[path], new_leaves[path]
        if not _finite_number(base_v) or not _finite_number(new_v):
            notes.append(f"{path}: non-finite value (base={base_v!r}, new={new_v!r}) — skipped")
            continue
        if base_v <= 0:
            notes.append(f"{path}: baseline {base_v} <= 0, ratio undefined — skipped")
            continue
        checked += 1
        ratio = float(new_v) / float(base_v)
        if new_v < base_v * (1.0 - tol):
            regressions.append((path, float(base_v), float(new_v), ratio))
        elif ratio > 1.0 + tol:
            improvements.append((path, float(base_v), float(new_v), ratio))
    return checked, regressions, improvements, notes


def _stream_p50s(block: dict):
    """(metric, direction, p50_list) triples of one reporter metrics block."""
    better = block.get("better") or {}
    for metric, aggs in (block.get("aggs") or {}).items():
        yield metric, better.get(metric, "none"), aggs.get("p50") or []


def compare_metrics(new: dict, base: dict, tol: float, streams=None):
    """Gate the windowed metric streams; returns (checked, regressions,
    notes).  ``regressions`` rows are (path, base, new, ratio) keyed
    ``metrics.<stream>.<metric>.p50[w]``.  ``streams`` (a compiled regex or
    None) restricts the gate to matching stream names."""
    new_m = new.get("metrics") or {}
    base_m = base.get("metrics") or {}
    regressions, notes = [], []
    checked = 0
    for stream in sorted(set(base_m) | set(new_m)):
        if streams is not None and not streams.search(stream):
            continue
        if stream not in new_m:
            notes.append(f"metrics.{stream}: in baseline only — skipped")
            continue
        if stream not in base_m:
            notes.append(f"metrics.{stream}: new stream, no baseline — skipped")
            continue
        nb, bb = new_m[stream], base_m[stream]
        if nb.get("window") != bb.get("window"):
            notes.append(
                f"metrics.{stream}: window {bb.get('window')} -> {nb.get('window')} changed — skipped"
            )
            continue
        new_p50 = {m: (d, p) for m, d, p in _stream_p50s(nb)}
        for metric, direction, base_p50 in _stream_p50s(bb):
            path = f"metrics.{stream}.{metric}.p50"
            if metric not in new_p50:
                notes.append(f"{path}: in baseline only — skipped")
                continue
            _, cur_p50 = new_p50[metric]
            if direction == "none":
                continue
            if len(cur_p50) != len(base_p50):
                notes.append(f"{path}: {len(base_p50)} -> {len(cur_p50)} windows — skipped")
                continue
            for w, (b, n) in enumerate(zip(base_p50, cur_p50)):
                if not _finite_number(b) or not _finite_number(n):
                    notes.append(f"{path}[{w}]: non-finite (base={b!r}, new={n!r}) — skipped")
                    continue
                checked += 1
                scale = abs(b) if b != 0 else 1.0
                ratio = n / b if b != 0 else float("inf") if n else 1.0
                if direction == "equal":
                    if abs(n - b) > EQUAL_RTOL * max(scale, 1.0):
                        regressions.append((f"{path}[{w}]", b, n, ratio))
                elif direction == "higher":
                    if b > 0 and n < b * (1.0 - tol):
                        regressions.append((f"{path}[{w}]", b, n, ratio))
                elif direction == "lower":
                    if n > b * (1.0 + tol) + EQUAL_RTOL:
                        regressions.append((f"{path}[{w}]", b, n, ratio))
                else:
                    notes.append(f"{path}: unknown direction {direction!r} — skipped")
                    break
    return checked, regressions, notes


def cold_autotune_note(path: str):
    """Informational only: a ``{"autotune": {"cold": true, ...}}`` block in a
    results file means its timings ran on untuned default tiles (the autotune
    cache had no entry for those sizes).  Reported, never gated — a cold
    cache is a provenance fact about the numbers, not a regression."""
    try:
        with open(path) as f:
            at = (json.load(f) or {}).get("autotune") or {}
    except (OSError, ValueError):
        return None
    if not at.get("cold"):
        return None
    keys = at.get("cold_keys") or []
    detail = f": {', '.join(keys[:4])}{', ...' if len(keys) > 4 else ''}" if keys else ""
    return f"autotune cache cold — timings used untuned default tiles ({len(keys)} key(s){detail})"


def compare_file(name: str, new_path: str, base_path: str, tol: float,
                 metrics_only: bool = False, streams=None):
    with open(new_path) as f:
        new = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    if metrics_only:
        checked_s, regs_s, imps, notes_s = 0, [], [], []
    else:
        checked_s, regs_s, imps, notes_s = compare_scalars(new, base, tol)
    checked_m, regs_m, notes_m = compare_metrics(new, base, tol, streams)
    return checked_s + checked_m, regs_s + regs_m, imps, notes_s + notes_m


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=os.environ.get("REPRO_BENCH_OUT", "results/bench"))
    ap.add_argument("--baseline", default=None, help="default: <results>/baseline")
    ap.add_argument("--tolerance", type=float, default=float(os.environ.get("BENCH_GATE_TOL", "0.30")))
    ap.add_argument("--soft", action="store_true", help="report regressions but exit 0")
    ap.add_argument("--update", action="store_true", help="copy current results over the baseline")
    ap.add_argument("--metrics-only", action="store_true",
                    help="gate only the windowed metric streams (PR metrics-diff step)")
    ap.add_argument("--streams", default=None, metavar="REGEX",
                    help="restrict the metrics gate to stream names matching this regex "
                         "(e.g. 'fairness' for the CI fairness step)")
    args = ap.parse_args()
    streams_re = re.compile(args.streams) if args.streams else None
    baseline = args.baseline or os.path.join(args.results, "baseline")

    names = sorted(
        f for f in (os.listdir(args.results) if os.path.isdir(args.results) else [])
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print(f"check_bench: no BENCH_*.json under {args.results}; nothing to do")
        return 0

    if args.update:
        os.makedirs(baseline, exist_ok=True)
        for f in names:
            shutil.copy2(os.path.join(args.results, f), os.path.join(baseline, f))
        print(f"check_bench: baseline updated with {len(names)} file(s): {', '.join(names)}")
        return 0

    any_regression = False
    for f in names:
        cold = cold_autotune_note(os.path.join(args.results, f))
        if cold:
            print(f"check_bench: {f}: note       {cold}")
        base_path = os.path.join(baseline, f)
        if not os.path.exists(base_path):
            print(f"check_bench: {f}: no baseline yet, skipping")
            continue
        checked, regs, imps, notes = compare_file(
            f, os.path.join(args.results, f), base_path, args.tolerance,
            args.metrics_only, streams_re,
        )
        status = "OK" if not regs else "REGRESSION"
        print(f"check_bench: {f}: {checked} metric(s) checked, {status}")
        for path, b, n, r in regs:
            any_regression = True
            print(f"  REGRESSION {path}: {b:.4g} -> {n:.4g} ({r:.2f}x, tolerance {1 - args.tolerance:.2f}x)")
        for path, b, n, r in imps:
            print(f"  improved   {path}: {b:.4g} -> {n:.4g} ({r:.2f}x)")
        for note in notes:
            print(f"  note       {note}")

    if any_regression and args.soft:
        print("check_bench: regressions found, but --soft set (cross-machine baseline) — not failing")
        return 0
    if any_regression:
        print(f"check_bench: FAILED — gated metrics regressed by more than {args.tolerance:.0%}")
        return 1
    print("check_bench: all gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
